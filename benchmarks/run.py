"""Benchmark harness — one function per paper table/figure.

  fig5a_comm      communication overhead per scheme & cut layer (paper Fig 5a)
  fig5b_time      overall training time per system design       (paper Fig 5b)
  fig5c_iid       test accuracy, IID data                       (paper Fig 5c)
  fig5d_noniid    test accuracy, non-IID data                   (paper Fig 5d)
  roofline        compute/memory/collective terms per (arch x shape x mesh)
                  from the dry-run artifact                     (EXPERIMENTS.md)

Prints ``name,us_per_call,derived`` CSV rows; detailed JSON lands in
benchmarks/out/.  ``--rounds/--steps`` control the accuracy runs (defaults
sized for the 1-core CPU container; pass --rounds 10 for paper-scale).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from bench_io import write_bench

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def _emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")


def _save(name: str, obj):
    # canonical location only (benchmarks/out/) — the fig5*/roofline
    # artifacts are not committed baselines, but they carry the same
    # provenance block (driver + argv) as the BENCH_* files
    write_bench(name, obj, "benchmarks/run.py", mirror_root=False)


# ---------------------------------------------------------------- Fig. 5a
def fig5a_comm() -> None:
    """Per-round communication overhead: FL vs SL/SFL at cuts 2/4/6/8
    (one local epoch, one round, 4 vehicles, batch 16 — paper setting)."""
    from repro.core.cost import (fl_client_round_cost, resnet_profile,
                                 sfl_client_round_cost)
    prof = resnet_profile()
    # paper scale: CIFAR-10 50k samples / 4 vehicles / batch 16 per epoch
    n_batches, batch, rate, cf, sf = 781, 16, 20e6, 20e9, 2e12
    rows = {}
    t0 = time.time()
    fl = fl_client_round_cost(prof, n_batches, batch, rate, cf, 1)
    rows["fl"] = fl.comm_bytes * 4
    for cut in (2, 4, 6, 8):
        c = sfl_client_round_cost(prof, cut, n_batches, batch, rate, cf, sf, 1)
        rows[f"sfl{cut}"] = c.comm_bytes * 4          # 4 vehicles
        rows[f"sl{cut}"] = c.comm_bytes * 4           # same bytes, serial time
    dt = (time.time() - t0) * 1e6
    _save("fig5a_comm", rows)
    order_ok = rows["sfl2"] > rows["sfl4"] > rows["sfl6"] > rows["sfl8"]
    sl_gg_fl = rows["sfl2"] > rows["fl"]
    for k, v in rows.items():
        _emit(f"fig5a_comm/{k}", dt / len(rows), f"{v/1e6:.1f}MB")
    _emit("fig5a_comm/claims", dt,
          f"decreasing_with_cut={order_ok};sfl2_gt_fl={sl_gg_fl}")


# ---------------------------------------------------------------- Fig. 5b
def fig5b_time() -> None:
    """Simulated overall training time per scheme (channel + compute model,
    heterogeneous fleet, one round of local epochs)."""
    from repro.core import channel
    from repro.core.adaptive import latency_optimal, paper_threshold
    from repro.core.cost import (fl_client_round_cost, resnet_profile,
                                 sfl_client_round_cost, sl_round_cost,
                                 parallel_round_latency)
    prof = resnet_profile()
    fleet = channel.make_fleet(4, seed=0)
    ch = channel.ChannelConfig()
    rates = channel.sample_round_rates(ch, fleet, t=5.0, seed=1)
    n_batches, batch, sf, epochs = 8, 16, 2e12, 5
    t0 = time.time()

    fl_costs = [fl_client_round_cost(prof, n_batches, batch, rates[i],
                                     fleet[i].compute_flops, epochs)
                for i in range(4)]
    t_fl = parallel_round_latency(fl_costs)

    sl = sl_round_cost(prof, 4, [n_batches] * 4, batch, rates,
                       [v.compute_flops for v in fleet], sf, epochs)
    t_sl = sl.latency

    t_sfl = {}
    for cut in (2, 4, 6, 8):
        cs = [sfl_client_round_cost(prof, cut, n_batches, batch, rates[i],
                                    fleet[i].compute_flops, sf, epochs)
              for i in range(4)]
        t_sfl[cut] = parallel_round_latency(cs)

    cuts_paper = paper_threshold(rates)
    cs = [sfl_client_round_cost(prof, cuts_paper[i], n_batches, batch,
                                rates[i], fleet[i].compute_flops, sf, epochs)
          for i in range(4)]
    t_asfl = parallel_round_latency(cs)

    cuts_opt = latency_optimal(prof, rates, [v.compute_flops for v in fleet],
                               sf, n_batches, batch, epochs)
    cs = [sfl_client_round_cost(prof, cuts_opt[i], n_batches, batch, rates[i],
                                fleet[i].compute_flops, sf, epochs)
          for i in range(4)]
    t_asfl_opt = parallel_round_latency(cs)

    dt = (time.time() - t0) * 1e6
    rows = {"fl": t_fl, "sl": t_sl,
            **{f"sfl{c}": t for c, t in t_sfl.items()},
            "asfl_paper_rule": t_asfl, "asfl_latency_opt": t_asfl_opt,
            "cuts_paper_rule": cuts_paper, "cuts_latency_opt": cuts_opt}
    _save("fig5b_time", rows)
    for k in ("fl", "sl", "sfl2", "sfl4", "sfl6", "sfl8", "asfl_paper_rule",
              "asfl_latency_opt"):
        _emit(f"fig5b_time/{k}", dt / 8, f"{rows[k]:.2f}s")
    _emit("fig5b_time/claims", dt,
          f"sl_worst={t_sl > max(t_fl, t_asfl)};asfl_lt_fl={t_asfl < t_fl};"
          f"opt_le_paper={t_asfl_opt <= t_asfl + 1e-9}")


# ------------------------------------------------------------ Fig. 5c / 5d
def _accuracy_run(iid: bool, rounds: int, local_steps: int,
                  schemes: List[str]) -> Dict[str, List[float]]:
    from repro.core.fedsim import FederationSim, ResNetModel, SimConfig
    from repro.data.pipeline import make_federated_data
    clients, test = make_federated_data(0, n_train=2048, n_test=512,
                                        n_clients=4, iid=iid)
    out = {}
    for scheme in schemes:
        name = scheme
        kwargs = dict(rounds=rounds, local_steps=local_steps, lr=1e-3,
                      batch_size=16)
        if scheme.startswith("sfl"):
            kwargs["cut"] = int(scheme[3:])
            sim_scheme = "sfl"
        elif scheme == "asfl":
            sim_scheme = "asfl"
        else:
            sim_scheme = scheme
        cfg = SimConfig(scheme=sim_scheme, **kwargs)
        sim = FederationSim(ResNetModel(), clients, test, cfg)
        hist = sim.run()
        out[name] = [m.test_acc for m in hist]
    return out


def fig5c_iid(rounds: int = 2, local_steps: int = 4) -> None:
    t0 = time.time()
    res = _accuracy_run(True, rounds, local_steps,
                        ["fl", "sl", "sfl2", "sfl4", "sfl6", "sfl8", "asfl"])
    dt = (time.time() - t0) * 1e6
    _save("fig5c_iid", res)
    for k, accs in res.items():
        _emit(f"fig5c_iid/{k}", dt / len(res), f"final_acc={accs[-1]:.3f}")


def fig5d_noniid(rounds: int = 2, local_steps: int = 4) -> None:
    t0 = time.time()
    res = _accuracy_run(False, rounds, local_steps,
                        ["fl", "sl", "sfl4", "asfl"])
    dt = (time.time() - t0) * 1e6
    _save("fig5d_noniid", res)
    for k, accs in res.items():
        _emit(f"fig5d_noniid/{k}", dt / len(res), f"final_acc={accs[-1]:.3f}")


# ----------------------------------------------------------------- roofline
def roofline(dryrun_json: str = None) -> None:
    """Three roofline terms per (arch x shape x mesh) from the dry-run."""
    path = dryrun_json or os.path.join(os.path.dirname(__file__), "..",
                                       "dryrun_baseline.json")
    if not os.path.exists(path):
        _emit("roofline/skip", 0.0, "no dryrun_baseline.json; run dryrun --all")
        return
    from repro.configs import get_config
    recs = json.load(open(path))
    table = []
    for r in recs:
        t_comp = r["flops_per_device"] / PEAK_FLOPS
        t_mem = r["traffic_per_device"] / HBM_BW
        t_coll = r["collective_bytes_per_device"] / ICI_BW
        dom = max((t_comp, "compute"), (t_mem, "memory"),
                  (t_coll, "collective"))
        n_active = r["active_params"]
        tokens = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
                  "decode_32k": 128, "long_500k": 1}[r["shape"]]
        mult = {"train": 3, "prefill": 1, "decode": 1}[r["kind"]]
        model_flops = 2 * n_active * tokens * mult
        hlo_total = r["flops_per_device"] * r["chips"]
        ratio = model_flops / hlo_total if hlo_total else 0.0
        row = {**{k: r[k] for k in ("arch", "shape", "mesh", "chips", "cut")},
               "t_compute_s": t_comp, "t_memory_s": t_mem,
               "t_collective_s": t_coll, "dominant": dom[1],
               "model_flops": model_flops, "hlo_flops_total": hlo_total,
               "useful_ratio": ratio}
        table.append(row)
        _emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
              max(t_comp, t_mem, t_coll) * 1e6,
              f"dom={dom[1]};comp={t_comp:.2e}s;mem={t_mem:.2e}s;"
              f"coll={t_coll:.2e}s;useful={ratio:.3f}")
    _save("roofline", table)


BENCHMARKS = {
    "fig5a_comm": fig5a_comm,
    "fig5b_time": fig5b_time,
    "fig5c_iid": fig5c_iid,
    "fig5d_noniid": fig5d_noniid,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--steps", type=int, default=4)
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHMARKS)
    print("name,us_per_call,derived")
    for n in names:
        fn = BENCHMARKS[n]
        if n in ("fig5c_iid", "fig5d_noniid"):
            fn(args.rounds, args.steps)
        else:
            fn()


if __name__ == "__main__":
    main()
